"""Quickstart: RoboECC in ~60 lines.

1. Build the OpenVLA layer graph (structure model, Eq. 1).
2. Find the optimal edge/cloud split under a cloud budget (Alg. 1).
3. Build the parameter-sharing pool and react to a bandwidth drop (§IV-B).
4. Execute a REAL co-inference on a reduced model with the split executor.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core import (Thresholds, Workload, adjust, build_graph,
                        build_pool, pool_transfer_profile, search)
from repro.core.hardware import A100, ORIN
from repro.models import build
from repro.runtime.partition import LMSplitExecutor, SplitPlan, payload_bytes

# --- 1. structure model -----------------------------------------------------
cfg = get_config("openvla-7b")
graph = build_graph(cfg, Workload())
print(f"{cfg.name}: {len(graph)} layers, "
      f"{sum(c.weight_bytes for c in graph) / 1e9:.1f} GB weights")

# --- 2. Alg. 1 segmentation --------------------------------------------------
seg = search(graph, ORIN, A100, bandwidth_bps=10e6,
             cloud_budget_bytes=12.1e9)
print(f"optimal split: layer {seg.split}/{len(graph)}  "
      f"total={seg.total_s * 1e3:.1f}ms "
      f"(edge {seg.edge_s * 1e3:.1f} + cloud {seg.cloud_s * 1e3:.1f} "
      f"+ net {seg.net_s * 1e3:.1f})")

# --- 3. pool + network-aware adjustment --------------------------------------
pool = build_pool(graph, seg.split, overhead_target=0.03)
print(f"parameter-sharing pool: layers [{pool.start},{pool.end}) "
      f"= {pool.overhead_frac * 100:.2f}% weight overhead")
thr = Thresholds(high=2e6, low=-2e6)
decision = adjust(graph, pool, seg.split, nb_pred_bps=1e6,
                  nb_real_bps=10e6, thr=thr)   # predictor says: dropping!
print(f"bandwidth 10->1 MB/s predicted: move split {seg.split} -> "
      f"{decision.split} ({decision.reason})")

# --- 4. real split execution on a reduced model -------------------------------
small = get_config("llama3.2-3b").reduced().replace(n_layers=8)
model = build(small)
params = model.init(jax.random.PRNGKey(0))
executor = LMSplitExecutor(small, SplitPlan(pool_start=3, pool_end=6,
                                            codec="int8"))
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 17), 0,
                            small.vocab_size)
for split in (3, 4, 5):
    logits, payload = executor.run(params, tokens, split)
    print(f"split={split}: edge->cloud payload "
          f"{payload_bytes(payload) / 1e3:.1f} KB, "
          f"logits {tuple(logits.shape)}")
print("OK")
