"""End-to-end driver: RoboECC serving a VLA under a fluctuating network.

Full pipeline: cost models -> Alg.1 -> pool -> trained LSTM predictor ->
per-request ΔNB adjustment, with a reduced CogACT actually executing split
co-inference (ViT+LLM on 'edge', LLM tail + DiT on 'cloud') and a seeded
bandwidth trace clocking every transfer.  Compares RoboECC against
edge-only / cloud-only / fixed-split and no-adjustment baselines.

    PYTHONPATH=src python examples/serve_vla_ecc.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (NetworkSim, PredictorConfig, RoboECC, Thresholds,
                        Workload, evaluate_split, fixed_split, generate_trace)
from repro.core.hardware import A100, ORIN
from repro.models import build
from repro.runtime.partition import SplitPlan, VLASplitExecutor, payload_bytes

N_REQUESTS = 60

# ---- control plane on the full-size CogACT ---------------------------------
cfg_full = get_config("cogact-7b")
workload = Workload(s_new=17, decode_steps=0)
ctl = RoboECC(cfg_full, ORIN, A100, workload=workload,
              cloud_budget_bytes=12.0e9,
              thresholds=Thresholds(high=1.5e6, low=-1.5e6))
trace = generate_trace(4000, seed=11)
t0 = time.time()
ctl.fit_predictor(trace[:3000], PredictorConfig(epochs=120))
print(f"LSTM predictor trained in {time.time() - t0:.1f}s "
      f"({ctl.predictor.n_bytes() / 1e3:.0f} KB)")
net = NetworkSim(trace[3000:])
net.step(ctl.predictor.cfg.window)
ctl.predictor.predict(net.window(ctl.predictor.cfg.window))  # jit warm-up
print(f"Alg.1: split {ctl.seg.split}/{len(ctl.graph)}, "
      f"pool [{ctl.pool.start},{ctl.pool.end}) "
      f"({ctl.pool.overhead_frac * 100:.2f}% overhead)")

# ---- data plane on a reduced CogACT ----------------------------------------
cfg = get_config("cogact-7b").reduced().replace(n_layers=6)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
Lv = cfg.vit_layers
executor = VLASplitExecutor(cfg, SplitPlan(Lv + 1, Lv + 5, codec="int8"))

def map_split(s):
    return executor.plan.clamp(Lv + round((s / len(ctl.graph)) * cfg.n_layers))

key = jax.random.PRNGKey(1)
lat_ecc, lat_noadj, wire = [], [], []
ctl_static = RoboECC(cfg_full, ORIN, A100, workload=workload,
                     cloud_budget_bytes=12.0e9)
net2 = NetworkSim(trace[3000:])
net2.step(ctl.predictor.cfg.window)
for rid in range(N_REQUESTS):
    tick = ctl.tick(net)
    lat_ecc.append(tick.total_s)
    lat_noadj.append(ctl_static.tick(net2, adjust_enabled=False).total_s)
    patches = jax.random.normal(key, (1, cfg.n_patches, cfg.vit_dim))
    tokens = jax.random.randint(key, (1, 17), 0, cfg.vocab_size)
    action, payload = executor.run(params, patches, tokens,
                                   map_split(tick.split), key)
    wire.append(payload_bytes(payload))
    if rid % 20 == 0:
        print(f"  req {rid:3d}: bw {tick.bw_real_bps / 1e6:5.2f} MB/s "
              f"pred {tick.bw_pred_bps / 1e6:5.2f}  split {tick.split} "
              f"total {tick.total_s * 1e3:6.1f} ms "
              f"action {tuple(np.asarray(action).shape)}")

# ---- baselines (modeled, same trace) ----------------------------------------
g, edge, cloud = ctl.graph, ctl.edge_dev, ctl.cloud_dev
eo = evaluate_split(g, len(g), edge, cloud, 10e6)[0]
co = sum(evaluate_split(g, 0, edge, cloud, 10e6,
                        input_bytes=workload.input_bytes)[1:])
fx = sum(evaluate_split(g, fixed_split(g), edge, cloud, 10e6)[:3])
warm_ecc = np.mean(lat_ecc[3:])      # skip jit warm-up ticks
warm_noadj = np.mean(lat_noadj[3:])
print(f"\nedge-only {eo * 1e3:7.1f} ms   cloud-only {co * 1e3:7.1f} ms   "
      f"fixed-split {fx * 1e3:7.1f} ms")
print(f"RoboECC     {warm_ecc * 1e3:7.1f} ms (p95 "
      f"{np.percentile(lat_ecc[3:], 95) * 1e3:.1f})   "
      f"no-adjustment {warm_noadj * 1e3:7.1f} ms")
print(f"speedup vs edge-only: x{eo / warm_ecc:.2f}   "
      f"cut payload {np.mean(wire) / 1e3:.1f} KB (int8 codec)")
assert warm_ecc <= warm_noadj * 1.25   # overhead stays small vs baseline
print("OK")
