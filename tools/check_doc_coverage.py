#!/usr/bin/env python
"""Doc-coverage checker: every public knob must be mentioned in the docs.

Extracts, by parsing the source with ``ast`` (no import of ``repro``, so
the check runs on any tree shape, including the unit tests' mini repos):

* every public field of the ``FleetConfig`` dataclass in
  ``src/repro/runtime/fleet.py`` (public = not underscore-prefixed), and
* every codec name registered by ``make_codecs`` in
  ``src/repro/core/codec.py`` (the ``out = {...}`` literal keys plus any
  ``out["name"] = ...`` assignments),

then requires each name to appear as a whole word somewhere in
``docs/*.md`` or ``README.md``.  A config field or codec that ships
without a single line of documentation fails CI with a pointed message.

This is the companion gate to ``check_doc_links.py``: that one keeps the
docs from citing files that do not exist; this one keeps the code from
growing knobs the docs never heard of.

    python tools/check_doc_coverage.py [--root PATH]
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from typing import List

FLEET_PY = os.path.join("src", "repro", "runtime", "fleet.py")
CODEC_PY = os.path.join("src", "repro", "core", "codec.py")
CONFIG_CLASS = "FleetConfig"
REGISTRY_FN = "make_codecs"
DOC_DIRS = ("docs",)                 # every *.md here
DOC_FILES = ("README.md",)           # plus these root files


def _parse(path: str, errors: List[str]) -> ast.Module:
    try:
        with open(path, encoding="utf-8") as f:
            return ast.parse(f.read(), filename=path)
    except (OSError, SyntaxError) as e:
        errors.append(f"{path}: cannot parse: {e}")
        return ast.Module(body=[], type_ignores=[])


def config_fields(root: str, errors: List[str]) -> List[str]:
    """Public annotated fields of FleetConfig, in declaration order."""
    tree = _parse(os.path.join(root, FLEET_PY), errors)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == CONFIG_CLASS:
            return [s.target.id for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)
                    and not s.target.id.startswith("_")]
    errors.append(f"{FLEET_PY}: class {CONFIG_CLASS!r} not found")
    return []


def codec_names(root: str, errors: List[str]) -> List[str]:
    """Registry keys built by make_codecs: dict-literal keys plus
    string-subscript assignments (``out["delta"] = ...``)."""
    tree = _parse(os.path.join(root, CODEC_PY), errors)
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == REGISTRY_FN:
            names: List[str] = []
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Assign):
                    continue
                for tgt in sub.targets:
                    if (isinstance(tgt, ast.Name)
                            and isinstance(sub.value, ast.Dict)):
                        names += [k.value for k in sub.value.keys
                                  if isinstance(k, ast.Constant)
                                  and isinstance(k.value, str)]
                    elif (isinstance(tgt, ast.Subscript)
                          and isinstance(tgt.slice, ast.Constant)
                          and isinstance(tgt.slice.value, str)):
                        names.append(tgt.slice.value)
            if not names:
                errors.append(f"{CODEC_PY}: {REGISTRY_FN} registers no "
                              "codec names the checker can see")
            return names
    errors.append(f"{CODEC_PY}: function {REGISTRY_FN!r} not found")
    return []


def _doc_corpus(root: str) -> str:
    chunks = []
    paths = [os.path.join(root, f) for f in DOC_FILES]
    for d in DOC_DIRS:
        base = os.path.join(root, d)
        if os.path.isdir(base):
            paths += [os.path.join(base, fn)
                      for fn in sorted(os.listdir(base))
                      if fn.endswith(".md")]
    for p in paths:
        if os.path.isfile(p):
            with open(p, encoding="utf-8") as f:
                chunks.append(f.read())
    return "\n".join(chunks)


def check(root: str) -> List[str]:
    errors: List[str] = []
    fields = config_fields(root, errors)
    codecs = codec_names(root, errors)
    corpus = _doc_corpus(root)
    where = "docs/*.md or " + "/".join(DOC_FILES)
    for name in fields:
        if not re.search(rf"\b{re.escape(name)}\b", corpus):
            errors.append(f"{CONFIG_CLASS}.{name}: public config field "
                          f"has no mention in {where}")
    for name in codecs:
        if not re.search(rf"\b{re.escape(name)}\b", corpus):
            errors.append(f"codec {name!r}: registered codec has no "
                          f"mention in {where}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."))
    args = ap.parse_args()
    errors = check(os.path.abspath(args.root))
    for e in errors:
        print(e)
    if errors:
        print(f"\n{len(errors)} undocumented public name(s)",
              file=sys.stderr)
        return 1
    print("doc coverage OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
