#!/usr/bin/env python
"""BENCH_fleet.json schema check: fail CI when the benchmark payload
drifts from what downstream consumers (perf-trajectory tooling, the
EXPERIMENTS.md tables, cross-PR diffs) expect.

The schema is versioned: ``benchmarks/fleet_bench.py`` stamps
``schema_version`` (currently 7 — the version that added the
``delta`` section: temporal-delta transport bytes-per-step by scene
class vs int4, key-frame rates, and the wire-bytes drift row auditing
the planner's cycle-average pricing against measured per-frame bytes)
and this checker validates

* the top-level sections and their per-entry keys,
* value sanity (latencies positive and finite, percentile ladders
  ordered p50 <= p95 <= p99 <= p99.9, counters non-negative, bubble
  fractions in [0, 1)),
* the planner section's parity wall-times,
* the scale section's engine tag and wall time (the CI scale-smoke step
  additionally asserts its wall budget against this payload),
* the scaling curve's monotonicity: sizes strictly increasing, peak RSS
  nondecreasing (it is a process high-water mark sampled in ascending
  size order), wall time nondecreasing up to a 20 % timing-noise
  allowance,
* the overhead section's ratios (>= 1 after the noise floor, sampled
  ratio inside its recorded budget) and walls,
* the delta section's per-scene byte accounting (bytes-per-step
  positive finite, key-frame rates in [0, 1], all three scene classes
  present) and its drift row (relative error inside the recorded
  tolerance),
* the drift section's join counts, per-stage error stats (finite), and
  the stage-sum reconciliation bound (< 1e-6 s — the recorder's
  decomposition must re-sum to the reported latency).

Run next to ``tools/check_doc_links.py`` in the workflow, after the
fleet smoke emits the file:

    python tools/check_bench_schema.py [--path BENCH_fleet.json]
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import List

EXPECTED_SCHEMA_VERSION = 7

TOP_SECTIONS = ("schema_version", "config", "planner", "fleet", "codecs",
                "multicut", "streamed", "queue", "delta", "scale",
                "scaling_curve", "autoscale", "overhead", "drift")
CONFIG_KEYS = ("n_robots", "n_ticks", "n_replicas", "seed", "smoke")
PLANNER_KEYS = ("scalar_s", "vec_s", "cells", "codec_scalar_s",
                "codec_vec_s", "codec_cells", "multicut_scalar_s",
                "multicut_vec_s", "multicut_cells", "multicut_speedup")
FLEET_KEYS = ("p50_s", "p95_s", "throughput_rps", "n_requests",
              "sim_wall_s")
CODEC_ENTRY_KEYS = ("p50_s", "p95_s", "throughput_rps")
MULTICUT_ENTRY_KEYS = ("p50_s", "p95_s", "n_multicut_requests")
STREAMED_ENTRY_KEYS = ("p50_s", "p95_s", "n_streamed_requests",
                       "n_chunk_reconfigs", "mean_bubble_frac")
QUEUE_ENTRY_KEYS = ("p50_s", "p95_s", "n_preemptions",
                    "mean_queue_delay_s", "kv_high_watermark_bytes")
# the queue comparison needs its baseline and both continuous rows
QUEUE_REQUIRED_TAGS = ("micro_blind", "cont_blind", "cont_aware")
SCALE_KEYS = ("engine", "n_robots", "n_ticks", "wall_s", "p50_s", "p95_s",
              "p99_s", "p999_s", "n_requests", "n_open_arrivals",
              "throughput_rps")
CURVE_KEYS = ("n_robots", "n_ticks", "wall_s", "peak_rss_bytes",
              "setup_s", "loop_s", "replan_s", "n_requests", "p999_s")
# wall time must grow with fleet size; small sizes finish in fractions
# of a second where scheduler noise is real, so allow a 20% dip
CURVE_WALL_TOLERANCE = 0.8
AUTOSCALE_ENTRY_KEYS = ("high_s", "n_autoscale_events", "p50_s", "p95_s",
                        "cohorts")
AUTOSCALE_COHORT_KEYS = ("p50_s", "p95_s", "n_arrivals", "n_rejected")
OVERHEAD_KEYS = ("n_robots", "n_ticks", "off_wall_s", "sampled_wall_s",
                 "full_wall_s", "sampled_ratio", "full_ratio",
                 "budget_ratio", "smoke", "n_recorded_sampled",
                 "n_recorded_full")
DRIFT_KEYS = ("n_joined", "n_pred_saturated", "reconcile_max_abs_s",
              "stages")
DRIFT_STAGE_KEYS = ("n", "mean_err", "p50_err", "p95_err")
DELTA_KEYS = ("resync_every", "static_gate_ratio", "scenes", "drift")
DELTA_SCENE_KEYS = ("delta_bytes_per_step", "int4_bytes_per_step",
                    "ratio_vs_int4", "keyframe_rate", "n_keyframes",
                    "n_delta_frames")
# the scene axis must carry the win case AND the honest negative
DELTA_REQUIRED_SCENES = ("static", "slow", "dynamic")
DELTA_DRIFT_KEYS = ("n", "mean_err_bytes", "p95_err_bytes",
                    "meas_mean_bytes", "rel_err", "rel_tol")
# the decomposition the recorder emits must re-sum to the latency it
# reports; anything past accumulated float rounding is a threading bug
DRIFT_RECONCILE_BOUND_S = 1e-6


def _finite_pos(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0


def check(payload: dict) -> List[str]:
    errs: List[str] = []

    def need(cond: bool, msg: str) -> None:
        if not cond:
            errs.append(msg)

    for k in TOP_SECTIONS:
        need(k in payload, f"missing top-level section {k!r}")
    if errs:
        return errs

    need(payload["schema_version"] == EXPECTED_SCHEMA_VERSION,
         f"schema_version {payload['schema_version']!r} != expected "
         f"{EXPECTED_SCHEMA_VERSION}")
    for k in CONFIG_KEYS:
        need(k in payload["config"], f"config missing {k!r}")
    for k in PLANNER_KEYS:
        need(k in payload["planner"], f"planner missing {k!r}")
    for k in ("scalar_s", "vec_s", "codec_scalar_s", "codec_vec_s",
              "multicut_scalar_s", "multicut_vec_s"):
        if k in payload["planner"]:
            need(_finite_pos(payload["planner"][k]),
                 f"planner.{k} must be finite positive")
    for k in FLEET_KEYS:
        need(k in payload["fleet"], f"fleet missing {k!r}")
    fl = payload["fleet"]
    if all(k in fl for k in ("p50_s", "p95_s")):
        need(_finite_pos(fl["p50_s"]) and _finite_pos(fl["p95_s"]),
             "fleet latencies must be finite positive")
        need(fl["p50_s"] <= fl["p95_s"], "fleet p50 > p95")

    def entries(section: str, keys) -> None:
        need(isinstance(payload[section], dict) and payload[section],
             f"section {section!r} must be a non-empty object")
        for tag, entry in payload.get(section, {}).items():
            for k in keys:
                need(k in entry, f"{section}[{tag!r}] missing {k!r}")
            if "p50_s" in entry and "p95_s" in entry:
                need(_finite_pos(entry["p50_s"])
                     and _finite_pos(entry["p95_s"]),
                     f"{section}[{tag!r}] latencies must be positive")
                need(entry["p50_s"] <= entry["p95_s"] + 1e-12,
                     f"{section}[{tag!r}] p50 > p95")

    entries("codecs", CODEC_ENTRY_KEYS)
    entries("multicut", MULTICUT_ENTRY_KEYS)
    entries("streamed", STREAMED_ENTRY_KEYS)
    entries("queue", QUEUE_ENTRY_KEYS)
    for t in QUEUE_REQUIRED_TAGS:
        need(t in payload.get("queue", {}), f"queue missing entry {t!r}")
    for tag, entry in payload.get("queue", {}).items():
        v = entry.get("n_preemptions")
        if v is not None:
            need(isinstance(v, int) and v >= 0,
                 f"queue[{tag!r}].n_preemptions must be a non-negative int")
        for k in ("mean_queue_delay_s", "kv_high_watermark_bytes"):
            v = entry.get(k)
            if v is not None:
                need(isinstance(v, (int, float)) and math.isfinite(v)
                     and v >= 0,
                     f"queue[{tag!r}].{k} must be non-negative finite")
    for tag, entry in payload.get("streamed", {}).items():
        bf = entry.get("mean_bubble_frac")
        if bf is not None:
            need(isinstance(bf, (int, float)) and 0.0 <= bf < 1.0,
                 f"streamed[{tag!r}].mean_bubble_frac out of [0, 1)")
        for k in ("n_streamed_requests", "n_chunk_reconfigs"):
            v = entry.get(k)
            if v is not None:
                need(isinstance(v, int) and v >= 0,
                     f"streamed[{tag!r}].{k} must be a non-negative int")
    # every operating point must carry BOTH modes for the comparison
    tags = set(payload.get("streamed", {}))
    for t in tags:
        if t.endswith("_seq"):
            need(t[:-4] + "_stream" in tags, f"streamed {t!r} lacks its "
                 f"'_stream' counterpart")

    de = payload["delta"]
    need(isinstance(de, dict) and de,
         "section 'delta' must be a non-empty object")
    if isinstance(de, dict) and de:
        for k in DELTA_KEYS:
            need(k in de, f"delta missing {k!r}")
        v = de.get("resync_every")
        if v is not None:
            need(isinstance(v, int) and v >= 1,
                 "delta.resync_every must be a positive int")
        scenes = de.get("scenes")
        need(isinstance(scenes, dict) and scenes,
             "delta.scenes must be a non-empty object")
        if isinstance(scenes, dict):
            for s in DELTA_REQUIRED_SCENES:
                need(s in scenes, f"delta.scenes missing {s!r}")
            for tag, entry in scenes.items():
                for k in DELTA_SCENE_KEYS:
                    need(k in entry, f"delta.scenes[{tag!r}] missing {k!r}")
                for k in ("delta_bytes_per_step", "int4_bytes_per_step",
                          "ratio_vs_int4"):
                    if k in entry:
                        need(_finite_pos(entry[k]),
                             f"delta.scenes[{tag!r}].{k} must be finite "
                             f"positive")
                kr = entry.get("keyframe_rate")
                if kr is not None:
                    need(isinstance(kr, (int, float)) and 0.0 <= kr <= 1.0,
                         f"delta.scenes[{tag!r}].keyframe_rate out of "
                         f"[0, 1]")
                for k in ("n_keyframes", "n_delta_frames"):
                    v = entry.get(k)
                    if v is not None:
                        need(isinstance(v, int) and v >= 0,
                             f"delta.scenes[{tag!r}].{k} must be a "
                             f"non-negative int")
        dd = de.get("drift")
        need(isinstance(dd, dict) and dd,
             "delta.drift must be a non-empty object")
        if isinstance(dd, dict) and dd:
            for k in DELTA_DRIFT_KEYS:
                need(k in dd, f"delta.drift missing {k!r}")
            v = dd.get("n")
            if v is not None:
                need(isinstance(v, int) and v > 0,
                     "delta.drift.n must be a positive int")
            for k in ("mean_err_bytes", "p95_err_bytes",
                      "meas_mean_bytes", "rel_err", "rel_tol"):
                v = dd.get(k)
                if v is not None:
                    need(isinstance(v, (int, float)) and math.isfinite(v),
                         f"delta.drift.{k} must be finite")
            rel, tol = dd.get("rel_err"), dd.get("rel_tol")
            if isinstance(rel, (int, float)) and isinstance(
                    tol, (int, float)):
                need(rel <= tol,
                     f"delta.drift.rel_err {rel!r} exceeds its recorded "
                     f"tolerance {tol!r}")

    sc = payload["scale"]
    need(isinstance(sc, dict), "section 'scale' must be an object")
    if isinstance(sc, dict):
        for k in SCALE_KEYS:
            need(k in sc, f"scale missing {k!r}")
        need(sc.get("engine") == "events",
             f"scale.engine {sc.get('engine')!r} != 'events'")
        need(_finite_pos(sc.get("wall_s", 0)),
             "scale.wall_s must be finite positive")
        for k in ("n_robots", "n_ticks", "n_requests", "n_open_arrivals"):
            v = sc.get(k)
            need(isinstance(v, int) and v >= 0,
                 f"scale.{k} must be a non-negative int")
        ladder = [sc.get(k) for k in ("p50_s", "p95_s", "p99_s", "p999_s")]
        if all(isinstance(v, (int, float)) for v in ladder):
            need(all(math.isfinite(v) and v > 0 for v in ladder),
                 "scale percentiles must be finite positive")
            need(all(a <= b + 1e-12 for a, b in zip(ladder, ladder[1:])),
                 "scale percentile ladder must be nondecreasing "
                 "(p50 <= p95 <= p99 <= p99.9)")

    curve = payload["scaling_curve"]
    need(isinstance(curve, list) and curve,
         "section 'scaling_curve' must be a non-empty list")
    if isinstance(curve, list) and curve:
        for i, row in enumerate(curve):
            for k in CURVE_KEYS:
                need(k in row, f"scaling_curve[{i}] missing {k!r}")
            for k in ("wall_s", "peak_rss_bytes"):
                if k in row:
                    need(_finite_pos(row[k]),
                         f"scaling_curve[{i}].{k} must be finite positive")
            for k in ("setup_s", "loop_s", "replan_s"):
                v = row.get(k)
                if v is not None:
                    need(isinstance(v, (int, float)) and math.isfinite(v)
                         and v >= 0,
                         f"scaling_curve[{i}].{k} must be non-negative "
                         f"finite")
            for k in ("n_robots", "n_ticks", "n_requests"):
                v = row.get(k)
                if v is not None:
                    need(isinstance(v, int) and v > 0,
                         f"scaling_curve[{i}].{k} must be a positive int")
        sizes = [r.get("n_robots") for r in curve]
        if all(isinstance(v, int) for v in sizes):
            need(all(a < b for a, b in zip(sizes, sizes[1:])),
                 "scaling_curve n_robots must be strictly increasing")
        rss = [r.get("peak_rss_bytes") for r in curve]
        if all(isinstance(v, (int, float)) for v in rss):
            need(all(a <= b for a, b in zip(rss, rss[1:])),
                 "scaling_curve peak_rss_bytes must be nondecreasing "
                 "(process high-water mark, sampled in ascending size "
                 "order)")
        walls = [r.get("wall_s") for r in curve]
        if all(isinstance(v, (int, float)) for v in walls):
            need(all(b >= a * CURVE_WALL_TOLERANCE
                     for a, b in zip(walls, walls[1:])),
                 "scaling_curve wall_s must be nondecreasing (within the "
                 f"{CURVE_WALL_TOLERANCE:.0%} timing-noise allowance)")

    asc = payload["autoscale"]
    need(isinstance(asc, dict) and asc,
         "section 'autoscale' must be a non-empty object")
    if isinstance(asc, dict):
        for tag, entry in asc.items():
            for k in AUTOSCALE_ENTRY_KEYS:
                need(k in entry, f"autoscale[{tag!r}] missing {k!r}")
            v = entry.get("n_autoscale_events")
            if v is not None:
                need(isinstance(v, int) and v >= 0,
                     f"autoscale[{tag!r}].n_autoscale_events must be a "
                     f"non-negative int")
            hs = entry.get("high_s")
            if hs is not None:
                need(_finite_pos(hs),
                     f"autoscale[{tag!r}].high_s must be finite positive")
            coh = entry.get("cohorts")
            need(isinstance(coh, dict) and coh,
                 f"autoscale[{tag!r}].cohorts must be a non-empty object")
            if isinstance(coh, dict):
                for cname, centry in coh.items():
                    for k in AUTOSCALE_COHORT_KEYS:
                        need(k in centry,
                             f"autoscale[{tag!r}].cohorts[{cname!r}] "
                             f"missing {k!r}")
                    for k in ("n_arrivals", "n_rejected"):
                        v = centry.get(k)
                        if v is not None:
                            need(isinstance(v, int) and v >= 0,
                                 f"autoscale[{tag!r}].cohorts[{cname!r}]"
                                 f".{k} must be a non-negative int")

    ov = payload["overhead"]
    need(isinstance(ov, dict) and ov,
         "section 'overhead' must be a non-empty object")
    if isinstance(ov, dict) and ov:
        for k in OVERHEAD_KEYS:
            need(k in ov, f"overhead missing {k!r}")
        for k in ("off_wall_s", "sampled_wall_s", "full_wall_s"):
            if k in ov:
                need(_finite_pos(ov[k]),
                     f"overhead.{k} must be finite positive")
        for k in ("sampled_ratio", "full_ratio"):
            v = ov.get(k)
            if v is not None:
                need(isinstance(v, (int, float)) and math.isfinite(v)
                     and v >= 1.0,
                     f"overhead.{k} must be >= 1 (noise-floored ratio)")
        br = ov.get("budget_ratio")
        if br is not None:
            need(_finite_pos(br) and br > 1.0,
                 "overhead.budget_ratio must be > 1")
            sr = ov.get("sampled_ratio")
            if isinstance(sr, (int, float)):
                need(sr <= br,
                     f"overhead.sampled_ratio {sr!r} exceeds its "
                     f"budget_ratio {br!r}")
        for k in ("n_recorded_sampled", "n_recorded_full"):
            v = ov.get(k)
            if v is not None:
                need(isinstance(v, int) and v > 0,
                     f"overhead.{k} must be a positive int")
        ns, nf = ov.get("n_recorded_sampled"), ov.get("n_recorded_full")
        if isinstance(ns, int) and isinstance(nf, int):
            need(ns <= nf, "overhead sampled mode recorded more "
                 "requests than full mode")

    dr = payload["drift"]
    need(isinstance(dr, dict) and dr,
         "section 'drift' must be a non-empty object")
    if isinstance(dr, dict) and dr:
        for k in DRIFT_KEYS:
            need(k in dr, f"drift missing {k!r}")
        for k in ("n_joined", "n_pred_saturated"):
            v = dr.get(k)
            if v is not None:
                need(isinstance(v, int) and v >= 0,
                     f"drift.{k} must be a non-negative int")
        need(isinstance(dr.get("n_joined"), int)
             and dr.get("n_joined", 0) > 0,
             "drift.n_joined must be positive (no requests were joined)")
        rc = dr.get("reconcile_max_abs_s")
        if rc is not None:
            need(isinstance(rc, (int, float)) and math.isfinite(rc)
                 and rc >= 0,
                 "drift.reconcile_max_abs_s must be non-negative finite")
            if isinstance(rc, (int, float)):
                need(rc < DRIFT_RECONCILE_BOUND_S,
                     f"drift stage sums diverge from measured latency by "
                     f"{rc!r} s (>= {DRIFT_RECONCILE_BOUND_S:g})")
        st = dr.get("stages")
        need(isinstance(st, dict) and st,
             "drift.stages must be a non-empty object")
        if isinstance(st, dict):
            for sname, sentry in st.items():
                for k in DRIFT_STAGE_KEYS:
                    need(k in sentry,
                         f"drift.stages[{sname!r}] missing {k!r}")
                v = sentry.get("n")
                if v is not None:
                    need(isinstance(v, int) and v > 0,
                         f"drift.stages[{sname!r}].n must be a "
                         f"positive int")
                for k in ("mean_err", "p50_err", "p95_err"):
                    v = sentry.get(k)
                    if v is not None:
                        need(isinstance(v, (int, float))
                             and math.isfinite(v),
                             f"drift.stages[{sname!r}].{k} must be "
                             f"finite")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--path", default="BENCH_fleet.json")
    args = ap.parse_args()
    try:
        with open(args.path, encoding="utf-8") as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{args.path}: cannot read/parse: {e}", file=sys.stderr)
        return 1
    errs = check(payload)
    for e in errs:
        print(f"{args.path}: {e}", file=sys.stderr)
    if errs:
        return 1
    static_ratio = payload["delta"]["scenes"]["static"]["ratio_vs_int4"]
    print(f"{args.path}: schema v{payload['schema_version']} OK "
          f"({len(payload['streamed'])} streamed, "
          f"{len(payload['queue'])} queue entries, "
          f"{len(payload['delta']['scenes'])} delta scenes "
          f"(static x{static_ratio:.1f} vs int4), scale "
          f"{payload['scale']['n_robots']} robots in "
          f"{payload['scale']['wall_s']:.1f}s, curve "
          f"{len(payload['scaling_curve'])} sizes up to "
          f"{payload['scaling_curve'][-1]['n_robots']}, "
          f"{len(payload['autoscale'])} autoscale points, telemetry "
          f"x{payload['overhead']['sampled_ratio']:.3f} sampled, "
          f"drift over {payload['drift']['n_joined']} requests)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
