#!/usr/bin/env python
"""Docs-link checker: fail on references to nonexistent repo files.

Scans

* every docstring in ``src/**/*.py`` (module / class / function, via
  ``ast``), and
* ``docs/*.md`` + ``README.md`` (both markdown link targets and inline
  path-like tokens),

extracts references that look like repo files (``*.py`` / ``*.md``) and
resolves each against (a) the repo root, (b) the referencing file's own
directory and its ancestors up to the repo root (so ``core/codec.py``
resolves from ``src/repro/runtime/fleet.py``), (c) ``docs/``, and — for
bare names like ``ops.py`` — (d) any file in the repo with that basename.
Unresolvable references are reported with file:line and exit status 1.

This is the guard that keeps docstrings honest: ``EXPERIMENTS.md`` and
``DESIGN.md`` were cited across ``src/`` for several PRs before either
file existed.

    python tools/check_doc_links.py [--root PATH]
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from typing import Iterator, List, Set, Tuple

REF_RE = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|md)\b")
MD_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)")
URL_RE = re.compile(r"\S+://\S+")    # strip URLs before REF_RE scans —
# otherwise `https://host/x.py` yields a bogus `host/x.py` repo ref
SCAN_DIRS = ("src",)
DOC_DIRS = ("docs",)                 # every *.md here
DOC_FILES = ("README.md",)           # plus these root files
SRC_ROOT = os.path.join("src", "repro")   # shorthand base: core/pool.py


def _docstrings(path: str) -> Iterator[Tuple[int, str]]:
    """(lineno, docstring) for every documented node in a Python file."""
    with open(path, encoding="utf-8") as f:
        try:
            tree = ast.parse(f.read(), filename=path)
        except SyntaxError as e:                     # pragma: no cover
            print(f"{path}: syntax error while parsing: {e}",
                  file=sys.stderr)
            return
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            doc = ast.get_docstring(node, clean=False)
            if doc:
                yield getattr(node, "lineno", 1), doc


def _basenames(root: str) -> Set[str]:
    names: Set[str] = set()
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".github")]
        names.update(filenames)
    return names


def _resolves(ref: str, src_dir: str, root: str, basenames: Set[str]) -> bool:
    if "://" in ref:
        return True                                  # URL, out of scope
    ref = ref.lstrip("./")
    candidates = [os.path.join(root, ref), os.path.join(src_dir, ref),
                  os.path.join(root, SRC_ROOT, ref)]
    # ancestors of the referencing file (src/repro/runtime -> src/repro ...)
    d = src_dir
    while os.path.realpath(d) != os.path.realpath(root):
        parent = os.path.dirname(d)
        if parent == d:
            break
        d = parent
        candidates.append(os.path.join(d, ref))
    candidates.append(os.path.join(root, "docs", ref))
    if any(os.path.isfile(c) for c in candidates):
        return True
    # bare name (no directory part): accept any repo file with that basename
    return "/" not in ref and os.path.basename(ref) in basenames


def check(root: str) -> List[str]:
    basenames = _basenames(root)
    errors: List[str] = []

    def scan_text(path: str, lineno: int, text: str) -> None:
        refs = set(m.group(0)
                   for m in REF_RE.finditer(URL_RE.sub(" ", text)))
        refs |= set(m.group(1) for m in MD_LINK_RE.finditer(text)
                    if m.group(1).endswith((".py", ".md"))
                    and "://" not in m.group(1))
        for ref in sorted(refs):
            if not _resolves(ref, os.path.dirname(path), root, basenames):
                rel = os.path.relpath(path, root)
                errors.append(f"{rel}:{lineno}: unresolved reference "
                              f"{ref!r}")

    for scan in SCAN_DIRS:
        base = os.path.join(root, scan)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    p = os.path.join(dirpath, fn)
                    for lineno, doc in _docstrings(p):
                        scan_text(p, lineno, doc)
    md_files = [os.path.join(root, f) for f in DOC_FILES]
    for d in DOC_DIRS:
        base = os.path.join(root, d)
        if os.path.isdir(base):
            md_files += [os.path.join(base, fn)
                         for fn in sorted(os.listdir(base))
                         if fn.endswith(".md")]
    for p in md_files:
        if not os.path.isfile(p):
            continue
        with open(p, encoding="utf-8") as f:
            for i, line in enumerate(f, 1):
                scan_text(p, i, line)
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."))
    args = ap.parse_args()
    errors = check(os.path.abspath(args.root))
    for e in errors:
        print(e)
    if errors:
        print(f"\n{len(errors)} unresolved repo-file reference(s)",
              file=sys.stderr)
        return 1
    print("doc links OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
